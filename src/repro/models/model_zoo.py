"""Unified model zoo: every assigned architecture as one parameterized
stack (dense / GQA / MoE / SSM / hybrid / enc-dec / VLM).

Design choices for multi-pod compilability (DESIGN.md §4):
  * scan-over-layers with stacked per-layer params — HLO size independent
    of depth (80-layer qwen1.5-110b compiles as one block);
  * optional remat per block;
  * blockwise attention beyond 8k sequence;
  * decode path threads a stacked KV/SSM cache through the same scan.

The modality frontends of whisper/paligemma are STUBS per the assignment:
`input_specs` provides precomputed frame/patch embeddings.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as ly
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.parallel import sharding as shd


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _scan_or_unroll(f, carry, xs, use_scan: bool):
    """lax.scan or an unrolled Python loop (probe path: while-loop-free
    HLO so cost_analysis is exact)."""
    if use_scan:
        return jax.lax.scan(f, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, out = f(carry, x_i)
        outs.append(out)
    if outs and outs[0] is not None:
        stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
    else:
        stacked = None
    return carry, stacked


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def _attn_params(pb, tree, cfg, prefix=""):
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    L = ("layers",)
    pb.add(tree, prefix + "wq", (cfg.n_layers, d, nh * hd),
           L + ("fsdp", "heads"))
    pb.add(tree, prefix + "wk", (cfg.n_layers, d, nkv * hd),
           L + ("fsdp", "kv"))
    pb.add(tree, prefix + "wv", (cfg.n_layers, d, nkv * hd),
           L + ("fsdp", "kv"))
    pb.add(tree, prefix + "wo", (cfg.n_layers, nh * hd, d),
           L + ("heads", "fsdp"))
    if cfg.qkv_bias:
        pb.add(tree, prefix + "bq", (cfg.n_layers, nh * hd), L + ("heads",),
               init="zeros")
        pb.add(tree, prefix + "bk", (cfg.n_layers, nkv * hd), L + ("kv",),
               init="zeros")
        pb.add(tree, prefix + "bv", (cfg.n_layers, nkv * hd), L + ("kv",),
               init="zeros")
    pb.add(tree, prefix + "ln_attn", (cfg.n_layers, d), L + (None,),
           init="ones")


def _mlp_params(pb, tree, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    L = ("layers",)
    pb.add(tree, "w_gate", (cfg.n_layers, d, ff), L + ("fsdp", "mlp"))
    pb.add(tree, "w_up", (cfg.n_layers, d, ff), L + ("fsdp", "mlp"))
    pb.add(tree, "w_down", (cfg.n_layers, ff, d), L + ("mlp", "fsdp"))
    pb.add(tree, "ln_mlp", (cfg.n_layers, d), L + (None,), init="ones")


def _moe_params(pb, tree, cfg):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = ("layers",)
    pb.add(tree, "router", (cfg.n_layers, d, e), L + ("embed", None),
           scale=0.02)
    pb.add(tree, "w_gate", (cfg.n_layers, e, d, ff),
           L + ("expert", "fsdp", "mlp"))
    pb.add(tree, "w_up", (cfg.n_layers, e, d, ff),
           L + ("expert", "fsdp", "mlp"))
    pb.add(tree, "w_down", (cfg.n_layers, e, ff, d),
           L + ("expert", "mlp", "fsdp"))
    if cfg.dense_residual_ff:
        rf = cfg.dense_residual_ff
        pb.add(tree, "res_gate", (cfg.n_layers, d, rf), L + ("fsdp", "mlp"))
        pb.add(tree, "res_up", (cfg.n_layers, d, rf), L + ("fsdp", "mlp"))
        pb.add(tree, "res_down", (cfg.n_layers, rf, d), L + ("mlp", "fsdp"))
    pb.add(tree, "ln_mlp", (cfg.n_layers, d), L + (None,), init="ones")


def _ssm_params(pb, tree, cfg):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.ssm_heads
    nl = cfg.n_layers
    L = ("layers",)
    proj_out = 2 * di + 2 * n + h
    pb.add(tree, "in_proj", (nl, d, proj_out), L + ("fsdp", "ssm_proj"))
    pb.add(tree, "conv_w", (nl, cfg.conv_width, di + 2 * n),
           L + ("conv", None))
    pb.add(tree, "conv_b", (nl, di + 2 * n), L + (None,), init="zeros")
    pb.add(tree, "dt_bias", (nl, h), L + ("ssm_heads",), init="zeros")
    pb.add(tree, "A", (nl, h), L + ("ssm_heads",), init="ssm_a")
    pb.add(tree, "D", (nl, h), L + ("ssm_heads",), init="ones")
    pb.add(tree, "norm", (nl, di), L + (None,), init="ones")
    pb.add(tree, "out_proj", (nl, di, d), L + ("ssm_proj", "fsdp"))
    pb.add(tree, "ln", (nl, d), L + (None,), init="ones")


def _shared_attn_params(pb, tree, cfg):
    """zamba2's single shared attention+MLP block (weights shared across
    all its applications)."""
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ff = cfg.d_ff
    pb.add(tree, "wq", (d, nh * hd), ("fsdp", "heads"))
    pb.add(tree, "wk", (d, nkv * hd), ("fsdp", "kv"))
    pb.add(tree, "wv", (d, nkv * hd), ("fsdp", "kv"))
    pb.add(tree, "wo", (nh * hd, d), ("heads", "fsdp"))
    pb.add(tree, "ln_attn", (d,), (None,), init="ones")
    pb.add(tree, "w_gate", (d, ff), ("fsdp", "mlp"))
    pb.add(tree, "w_up", (d, ff), ("fsdp", "mlp"))
    pb.add(tree, "w_down", (ff, d), ("mlp", "fsdp"))
    pb.add(tree, "ln_mlp", (d,), (None,), init="ones")


def _enc_params(pb, tree, cfg):
    """Whisper encoder stack (bidirectional) + cross-attention K/V projs
    for the decoder."""
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ne = cfg.n_enc_layers
    L = ("layers",)
    pb.add(tree, "enc_wq", (ne, d, nh * hd), L + ("fsdp", "heads"))
    pb.add(tree, "enc_wk", (ne, d, nkv * hd), L + ("fsdp", "kv"))
    pb.add(tree, "enc_wv", (ne, d, nkv * hd), L + ("fsdp", "kv"))
    pb.add(tree, "enc_wo", (ne, nh * hd, d), L + ("heads", "fsdp"))
    pb.add(tree, "enc_ln_attn", (ne, d), L + (None,), init="ones")
    pb.add(tree, "enc_w_gate", (ne, d, cfg.d_ff), L + ("fsdp", "mlp"))
    pb.add(tree, "enc_w_up", (ne, d, cfg.d_ff), L + ("fsdp", "mlp"))
    pb.add(tree, "enc_w_down", (ne, cfg.d_ff, d), L + ("mlp", "fsdp"))
    pb.add(tree, "enc_ln_mlp", (ne, d), L + (None,), init="ones")
    pb.add(tree, "enc_pos", (cfg.enc_positions, d), ("frames", None),
           scale=0.02)
    # decoder cross-attention
    nl = cfg.n_layers
    pb.add(tree, "x_wq", (nl, d, nh * hd), L + ("fsdp", "heads"))
    pb.add(tree, "x_wk", (nl, d, nkv * hd), L + ("fsdp", "kv"))
    pb.add(tree, "x_wv", (nl, d, nkv * hd), L + ("fsdp", "kv"))
    pb.add(tree, "x_wo", (nl, nh * hd, d), L + ("heads", "fsdp"))
    pb.add(tree, "x_ln", (nl, d), L + (None,), init="ones")


def build_params(cfg: ModelConfig, rng: Optional[jax.Array] = None,
                 abstract: bool = False):
    """Returns (params, axes) — axes is the logical-annotation tree for
    axes_to_specs."""
    pdt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    pb = ly.ParamBuilder(rng if not abstract else None, abstract, pdt)
    tree: Dict[str, Any] = {}
    pb.add(tree, "embed", (cfg.vocab_padded, cfg.d_model),
           ("vocab", "embed"), scale=0.02)
    pb.add(tree, "ln_f", (cfg.d_model,), (None,), init="ones")
    if not cfg.tie_embeddings:
        pb.add(tree, "unembed", (cfg.d_model, cfg.vocab_padded),
               ("embed", "vocab"), scale=0.02)

    fam = cfg.family
    if fam in ("dense", "vlm", "encdec"):
        _attn_params(pb, tree, cfg)
        _mlp_params(pb, tree, cfg)
        if fam == "encdec":
            _enc_params(pb, tree, cfg)
    elif fam == "moe":
        _attn_params(pb, tree, cfg)
        _moe_params(pb, tree, cfg)
    elif fam == "ssm":
        _ssm_params(pb, tree, cfg)
    elif fam == "hybrid":
        _ssm_params(pb, tree, cfg)
        shared: Dict[str, Any] = {}
        _shared_attn_params(pb, shared, cfg)
        tree["shared_attn"] = shared
    else:
        raise ValueError(fam)
    return ly.split_axes(tree)


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------

def _attn_block(x, p, cfg, mask_mode, prefix_len, cdt,
                kv_override=None, q_offset=None, cache=None, cache_len=None):
    """Pre-norm attention block. p holds per-layer (unstacked) params.
    Returns (out, new_kv) where new_kv is (k, v) of this call."""
    b, s, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    h = ly.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    wq = shd.gather_param(p["wq"].astype(cdt), "fsdp", "heads")
    wk = shd.gather_param(p["wk"].astype(cdt), "fsdp", "kv")
    wv = shd.gather_param(p["wv"].astype(cdt), "fsdp", "kv")
    wo = shd.gather_param(p["wo"].astype(cdt), "heads", "fsdp")
    q = jnp.einsum("bsd,dq->bsq", h, wq)
    src = h if kv_override is None else kv_override
    k = jnp.einsum("bsd,dq->bsq", src, wk)
    v = jnp.einsum("bsd,dq->bsq", src, wv)
    if cfg.qkv_bias and "bq" in p:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, src.shape[1], nkv, hd)
    v = v.reshape(b, src.shape[1], nkv, hd)
    q = shd.constrain(q, "batch", "cp_q", "heads", None)
    k = shd.constrain(k, "batch", "seq", "kv", None)
    v = shd.constrain(v, "batch", "seq", "kv", None)
    if mask_mode != "full" or kv_override is None:
        pos_q = (jnp.arange(s) if q_offset is None
                 else q_offset + jnp.arange(s))
        q = ly.rope(q, pos_q[None, :], cfg.rope_theta)
        if cache is None or kv_override is None:
            pos_k = jnp.arange(k.shape[1]) if cache is None else pos_q
            k = ly.rope(k, pos_k[None, :], cfg.rope_theta)
    if cache is not None:
        if len(cache) == 4:          # int8 cache with per-token scales
            k_cache, v_cache, k_sc, v_sc = cache
            kq, ks = attn.quantize_kv(k)
            vq, vs = attn.quantize_kv(v)
            # re-pin shardings post-update: dynamic_update_slice into a
            # seq-sharded cache otherwise resolves via a full all-gather
            # of the cache slice every step (§Perf iter C3)
            k_cache = shd.constrain(jax.lax.dynamic_update_slice_in_dim(
                k_cache, kq, cache_len, axis=1),
                "batch", "kvseq", "kv", None)
            v_cache = shd.constrain(jax.lax.dynamic_update_slice_in_dim(
                v_cache, vq, cache_len, axis=1),
                "batch", "kvseq", "kv", None)
            k_sc = shd.constrain(jax.lax.dynamic_update_slice_in_dim(
                k_sc, ks, cache_len, axis=1), "batch", "kvseq", "kv")
            v_sc = shd.constrain(jax.lax.dynamic_update_slice_in_dim(
                v_sc, vs, cache_len, axis=1), "batch", "kvseq", "kv")
            o = attn.decode_attention_q8(q, k_cache, v_cache, k_sc, v_sc,
                                         cache_len + s)
            new_cache = (k_cache, v_cache, k_sc, v_sc)
        else:
            k_cache, v_cache = cache
            k_cache = shd.constrain(jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), cache_len, axis=1),
                "batch", "kvseq", "kv", None)
            v_cache = shd.constrain(jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), cache_len, axis=1),
                "batch", "kvseq", "kv", None)
            o = attn.decode_attention(q, k_cache, v_cache, cache_len + s)
            new_cache = (k_cache, v_cache)
    else:
        o = attn.attention(q, k, v, mask_mode, prefix_len,
                           unroll=not cfg.scan_layers)
        new_cache = (k, v)
    o = o.reshape(b, s, nh * hd)
    out = jnp.einsum("bsq,qd->bsd", o, wo)
    out = shd.constrain(out, "batch", "cp_seq", "embed")
    return out, new_cache


def _mlp_block(x, p, cfg, cdt):
    h = ly.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    return ly.swiglu(h, p["w_gate"].astype(cdt), p["w_up"].astype(cdt),
                     p["w_down"].astype(cdt), cdt)


def _moe_block(x, p, cfg, cdt):
    h = ly.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    return moe_mod.moe_block(h, p, cfg, cdt)


def _layer_keys(params, cfg):
    """Names of the per-layer stacked decoder params."""
    fam = cfg.family
    keys = []
    if fam in ("dense", "vlm", "encdec", "moe"):
        keys += ["wq", "wk", "wv", "wo", "ln_attn", "ln_mlp"]
        if cfg.qkv_bias:
            keys += ["bq", "bk", "bv"]
        if fam == "moe":
            keys += ["router", "w_gate", "w_up", "w_down"]
            if cfg.dense_residual_ff:
                keys += ["res_gate", "res_up", "res_down"]
        else:
            keys += ["w_gate", "w_up", "w_down"]
        if fam == "encdec":
            keys += ["x_wq", "x_wk", "x_wv", "x_wo", "x_ln"]
    elif fam in ("ssm", "hybrid"):
        keys += ["in_proj", "conv_w", "conv_b", "dt_bias", "A", "D",
                 "norm", "out_proj", "ln"]
    return [k for k in keys if k in params]


def _decoder_block(x, lp, cfg, cdt, mask_mode="causal", prefix_len=0,
                   enc_out=None):
    """One decoder layer (training/prefill path)."""
    fam = cfg.family
    if fam in ("ssm", "hybrid"):
        h = ly.rms_norm(x, lp["ln"], cfg.norm_eps)
        return x + ssm_mod.mamba2_block(h, lp, cfg, cdt)
    a, _ = _attn_block(x, lp, cfg, mask_mode, prefix_len, cdt)
    x = x + a
    if fam == "encdec" and enc_out is not None:
        h = ly.rms_norm(x, lp["x_ln"], cfg.norm_eps)
        xa, _ = _attn_block(
            x, {"wq": lp["x_wq"], "wk": lp["x_wk"], "wv": lp["x_wv"],
                "wo": lp["x_wo"], "ln_attn": lp["x_ln"]},
            cfg, "full", 0, cdt, kv_override=enc_out)
        x = x + xa
    if fam == "moe":
        x = x + _moe_block(x, lp, cfg, cdt)
    else:
        x = x + _mlp_block(x, lp, cfg, cdt)
    return x


def _scan_layers(x, params, cfg, cdt, mask_mode="causal", prefix_len=0,
                 enc_out=None):
    keys = _layer_keys(params, cfg)
    stacked = {k: params[k] for k in keys}
    shared = params.get("shared_attn")

    def block(carry, lp_and_idx):
        h, = carry
        lp, idx = lp_and_idx
        h = _decoder_block(h, lp, cfg, cdt, mask_mode, prefix_len, enc_out)
        if cfg.family == "hybrid" and cfg.attn_every:
            def with_attn(h):
                a, _ = _attn_block(h, shared, cfg, mask_mode, prefix_len,
                                   cdt)
                h = h + a
                h = h + _mlp_block(h, shared, cfg, cdt)
                return h
            h = jax.lax.cond(
                (idx + 1) % cfg.attn_every == 0, with_attn, lambda v: v, h)
        return (h,), None

    if cfg.remat != "none":
        block = jax.checkpoint(block, prevent_cse=False)
    idxs = jnp.arange(cfg.n_layers)
    (x,), _ = _scan_or_unroll(block, (x,), (stacked, idxs),
                              cfg.scan_layers)
    return x


# ---------------------------------------------------------------------------
# public forward passes
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens, cdt):
    x = params["embed"].astype(cdt)[tokens]
    return shd.constrain(x, "batch", "seq", "embed")


def logits_out(params, cfg, x, cdt):
    x = ly.rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = (params["embed"] if cfg.tie_embeddings
             else params["unembed"].T).astype(cdt)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    return shd.constrain(logits, "batch", "seq", "vocab")


def _encode(params, cfg, frames, cdt):
    """Whisper encoder over stub frame embeddings [B, T_enc, d]."""
    x = frames.astype(cdt) + params["enc_pos"].astype(cdt)[None]
    ne = cfg.n_enc_layers
    stacked = {k[len("enc_"):]: params[k] for k in (
        "enc_wq", "enc_wk", "enc_wv", "enc_wo", "enc_ln_attn",
        "enc_w_gate", "enc_w_up", "enc_w_down", "enc_ln_mlp")}

    def block(h, lp):
        a, _ = _attn_block(h, lp, cfg, "full", 0, cdt)
        h = h + a
        h = h + _mlp_block(h, lp, cfg, cdt)
        return h, None

    blk = jax.checkpoint(block) if cfg.remat != "none" else block
    x, _ = _scan_or_unroll(blk, x, stacked, cfg.scan_layers)
    return x


def forward(params, cfg: ModelConfig, tokens, frontend=None):
    """Training/prefill forward -> logits.
    frontend: stub modality input — whisper frame embeddings or paligemma
    patch embeddings (assignment: frontends are stubs)."""
    cdt = _dt(cfg)
    x = embed_tokens(params, cfg, tokens, cdt)
    mask_mode, prefix_len, enc_out = "causal", 0, None
    if cfg.family == "vlm" and frontend is not None:
        x = jnp.concatenate([frontend.astype(cdt), x], axis=1)
        mask_mode, prefix_len = "prefix", cfg.img_tokens
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, frontend, cdt)
    x = _scan_layers(x, params, cfg, cdt, mask_mode, prefix_len, enc_out)
    if cfg.family == "vlm" and frontend is not None:
        x = x[:, cfg.img_tokens:]
    return logits_out(params, cfg, x, cdt)


# ---------------------------------------------------------------------------
# decode (serving) path
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               abstract: bool = False):
    """Stacked decode cache. Attention archs: K/V per layer; SSM/hybrid:
    ssm state + conv buffer (+ shared-attn K/V for hybrid)."""
    cdt = _dt(cfg)
    nkv, hd = cfg.n_kv, cfg.head_dim

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    cache: Dict[str, Any] = {}
    q8 = (cfg.kv_cache_dtype == "int8"
          and cfg.family in ("dense", "vlm", "moe"))
    kv_dt = jnp.int8 if q8 else cdt
    if cfg.family in ("dense", "vlm", "encdec", "moe"):
        cache["k"] = mk((cfg.n_layers, batch, max_seq, nkv, hd), kv_dt)
        cache["v"] = mk((cfg.n_layers, batch, max_seq, nkv, hd), kv_dt)
        if q8:
            cache["k_sc"] = mk((cfg.n_layers, batch, max_seq, nkv),
                               jnp.float32)
            cache["v_sc"] = mk((cfg.n_layers, batch, max_seq, nkv),
                               jnp.float32)
        if cfg.family == "encdec":
            cache["xk"] = mk((cfg.n_layers, batch, cfg.enc_positions,
                              nkv, hd), cdt)
            cache["xv"] = mk((cfg.n_layers, batch, cfg.enc_positions,
                              nkv, hd), cdt)
    if cfg.family in ("ssm", "hybrid"):
        di, n = cfg.d_inner, cfg.ssm_state
        cache["ssm"] = mk((cfg.n_layers, batch, cfg.ssm_heads, n,
                           cfg.ssm_head_dim), jnp.float32)
        cache["conv"] = mk((cfg.n_layers, batch, cfg.conv_width - 1,
                            di + 2 * n), cdt)
        if cfg.family == "hybrid" and cfg.attn_every:
            n_attn = cfg.n_layers // cfg.attn_every
            cache["k"] = mk((n_attn, batch, max_seq, nkv, hd), cdt)
            cache["v"] = mk((n_attn, batch, max_seq, nkv, hd), cdt)
    return cache


def cache_axes(cfg: ModelConfig):
    """Logical axes for the cache tree (for shardings)."""
    ax: Dict[str, Any] = {}
    if cfg.family in ("dense", "vlm", "encdec", "moe") or (
            cfg.family == "hybrid" and cfg.attn_every):
        ax["k"] = ("layers", "batch", "kvseq", "kv", None)
        ax["v"] = ("layers", "batch", "kvseq", "kv", None)
        if cfg.kv_cache_dtype == "int8" and cfg.family in (
                "dense", "vlm", "moe"):
            ax["k_sc"] = ("layers", "batch", "kvseq", "kv")
            ax["v_sc"] = ("layers", "batch", "kvseq", "kv")
    if cfg.family == "encdec":
        ax["xk"] = ("layers", "batch", "frames", "kv", None)
        ax["xv"] = ("layers", "batch", "frames", "kv", None)
    if cfg.family in ("ssm", "hybrid"):
        ax["ssm"] = ("layers", "batch", "ssm_heads", "state", None)
        ax["conv"] = ("layers", "batch", "conv", None)
    return ax


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_len):
    """One decode step: tokens [B, 1] + cache -> (logits [B,1,V], cache).
    cache_len: current filled length (int32 scalar)."""
    cdt = _dt(cfg)
    b = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens, cdt)
    keys = _layer_keys(params, cfg)
    stacked = {k: params[k] for k in keys}
    fam = cfg.family
    shared = params.get("shared_attn")

    q8 = "k_sc" in cache
    if fam in ("dense", "vlm", "encdec", "moe"):
        def block(carry, xs):
            h, = carry
            if fam == "encdec":
                lp, kc, vc, xkc, xvc = xs
                cc = (kc, vc)
            elif q8:
                lp, kc, vc, ksc, vsc = xs
                cc = (kc, vc, ksc, vsc)
            else:
                lp, kc, vc = xs
                cc = (kc, vc)
            a, cc = _attn_block(
                h, lp, cfg, "causal", 0, cdt, q_offset=cache_len,
                cache=cc, cache_len=cache_len)
            h = h + a
            if fam == "encdec":
                xa = attn.decode_attention(
                    jnp.einsum("bsd,dq->bsq",
                               ly.rms_norm(h, lp["x_ln"], cfg.norm_eps),
                               lp["x_wq"].astype(cdt)
                               ).reshape(b, 1, cfg.n_heads, cfg.head_dim),
                    xkc, xvc, jnp.array(cfg.enc_positions, jnp.int32))
                xa = jnp.einsum(
                    "bsq,qd->bsd", xa.reshape(b, 1, -1),
                    lp["x_wo"].astype(cdt))
                h = h + xa
            h = h + (_moe_block(h, lp, cfg, cdt) if fam == "moe"
                     else _mlp_block(h, lp, cfg, cdt))
            if fam == "encdec":
                out = (cc[0], cc[1], xkc, xvc)
            else:
                out = cc
            return (h,), out

        if fam == "encdec":
            xs = (stacked, cache["k"], cache["v"], cache["xk"],
                  cache["xv"])
        elif q8:
            xs = (stacked, cache["k"], cache["v"], cache["k_sc"],
                  cache["v_sc"])
        else:
            xs = (stacked, cache["k"], cache["v"])
        (x,), outs = _scan_or_unroll(block, (x,), xs, cfg.scan_layers)
        if fam == "encdec":
            cache = dict(cache, k=outs[0], v=outs[1], xk=outs[2],
                         xv=outs[3])
        elif q8:
            cache = dict(cache, k=outs[0], v=outs[1], k_sc=outs[2],
                         v_sc=outs[3])
        else:
            cache = dict(cache, k=outs[0], v=outs[1])
    else:  # ssm / hybrid
        n_attn = (cfg.n_layers // cfg.attn_every
                  if fam == "hybrid" and cfg.attn_every else 0)

        def block(carry, xs):
            h, attn_i, k_all, v_all = carry
            lp, sc, cc, idx = xs
            hn = ly.rms_norm(h, lp["ln"], cfg.norm_eps)
            y, new_state = ssm_mod.mamba2_decode(
                hn, {"ssm": sc, "conv": cc}, lp, cfg, cdt)
            h = h + y
            if n_attn:
                # interleaved shared attention, same schedule as training
                def with_attn(op):
                    h, attn_i, k_all, v_all = op
                    kc = jax.lax.dynamic_index_in_dim(
                        k_all, attn_i, axis=0, keepdims=False)
                    vc = jax.lax.dynamic_index_in_dim(
                        v_all, attn_i, axis=0, keepdims=False)
                    a, (kc, vc) = _attn_block(
                        h, shared, cfg, "causal", 0, cdt,
                        q_offset=cache_len, cache=(kc, vc),
                        cache_len=cache_len)
                    h = h + a
                    h = h + _mlp_block(h, shared, cfg, cdt)
                    k_all = jax.lax.dynamic_update_index_in_dim(
                        k_all, kc, attn_i, axis=0)
                    v_all = jax.lax.dynamic_update_index_in_dim(
                        v_all, vc, attn_i, axis=0)
                    return h, attn_i + 1, k_all, v_all

                h, attn_i, k_all, v_all = jax.lax.cond(
                    (idx + 1) % cfg.attn_every == 0, with_attn,
                    lambda op: op, (h, attn_i, k_all, v_all))
            return (h, attn_i, k_all, v_all), (
                new_state["ssm"], new_state["conv"])

        idxs = jnp.arange(cfg.n_layers)
        k0 = cache.get("k", jnp.zeros((1, 1, 1, 1, 1), cdt))
        v0 = cache.get("v", jnp.zeros((1, 1, 1, 1, 1), cdt))
        (x, _, k_new, v_new), (ssm_new, conv_new) = _scan_or_unroll(
            block, (x, jnp.array(0, jnp.int32), k0, v0),
            (stacked, cache["ssm"], cache["conv"], idxs),
            cfg.scan_layers)
        cache = dict(cache, ssm=ssm_new, conv=conv_new)
        if n_attn:
            cache = dict(cache, k=k_new, v=v_new)

    return logits_out(params, cfg, x, cdt), cache
