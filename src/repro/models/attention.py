"""GQA/MQA/MHA attention: full, blockwise (online-softmax) and decode.

Blockwise attention scans KV chunks with a running (max, sum) — O(seq)
memory, compact HLO under scan, and the natural remat boundary for the
32k-prefill shapes.  Masks: causal, prefix-LM (paligemma), full (whisper
encoder / cross-attention).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel import sharding as shd

BLOCKWISE_THRESHOLD = 2048
KV_CHUNK = 1024


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def _mask_bias(mask_mode: str, q_pos, k_pos, prefix_len: int, dtype):
    """[q, k] additive bias."""
    if mask_mode == "full":
        return None
    ok = k_pos[None, :] <= q_pos[:, None]
    if mask_mode == "prefix":
        ok = ok | (k_pos[None, :] < prefix_len)
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def full_attention(q, k, v, mask_mode: str = "causal",
                   prefix_len: int = 0, q_offset=None):
    """q [b,sq,h,d], k/v [b,sk,kv,d] (kv repeated to h by caller or here)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    logits = shd.constrain(logits, "batch", "heads", None, None)
    q_pos = (jnp.arange(sq) if q_offset is None
             else q_offset + jnp.arange(sq))
    bias = _mask_bias(mask_mode, q_pos, jnp.arange(sk), prefix_len,
                      jnp.float32)
    l32 = logits.astype(jnp.float32)
    if bias is not None:
        l32 = l32 + bias[None, None]
    probs = jax.nn.softmax(l32, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return shd.constrain(out, "batch", "seq", "heads", None)


def blockwise_attention(q, k, v, mask_mode: str = "causal",
                        prefix_len: int = 0, kv_chunk: int = KV_CHUNK,
                        unroll: bool = False):
    """Online-softmax attention, scanning KV chunks. O(sq * kv_chunk) live
    memory instead of O(sq*sk).  unroll=True replaces the scan with an
    unrolled loop (dry-run probe path: exact cost_analysis)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = d ** -0.5
    n_chunks = sk // kv_chunk
    k = k.reshape(b, n_chunks, kv_chunk, h, d)
    v = v.reshape(b, n_chunks, kv_chunk, h, d)
    q_pos = jnp.arange(sq)

    def chunk_step(carry, kv_c):
        m_prev, s_prev, o_prev, c_idx = carry
        k_c, v_c = kv_c
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_c).astype(
            jnp.float32) * scale
        logits = shd.constrain(logits, "batch", "heads", "cp_seq", None)
        k_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)
        bias = _mask_bias(mask_mode, q_pos, k_pos, prefix_len, jnp.float32)
        if bias is not None:
            logits = logits + bias[None, None]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[..., None])
        p = shd.constrain(p, "batch", "heads", "cp_seq", None)
        s_new = s_prev * alpha + p.sum(axis=-1)
        o_new = (o_prev * alpha[..., None] +
                 jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), v_c
                            ).astype(jnp.float32))
        o_new = shd.constrain(o_new, "batch", "heads", "cp_seq", None)
        return (m_new, s_new, o_new, c_idx + 1), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    s0 = jnp.zeros((b, h, sq), jnp.float32)
    o0 = jnp.zeros((b, h, sq, d), jnp.float32)
    carry = (m0, s0, o0, jnp.array(0, jnp.int32))
    xs = (k.swapaxes(0, 1), v.swapaxes(0, 1))
    if unroll:
        for i in range(n_chunks):
            carry, _ = chunk_step(carry, jax.tree.map(lambda a: a[i], xs))
        m, s, o, _ = carry
    else:
        (m, s, o, _), _ = jax.lax.scan(chunk_step, carry, xs)
    out = (o / jnp.maximum(s, 1e-30)[..., None]).astype(q.dtype)
    out = out.transpose(0, 2, 1, 3)   # [b, sq, h, d]
    return shd.constrain(out, "batch", "cp_seq", "heads", None)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-position decode: q [b,1,h,d] against cache [b,sk,kv,d].
    Grouped-query einsum — the KV cache is NEVER broadcast to h heads
    (repeat_kv would multiply decode HBM traffic by h/kv; §Perf iter C2).
    Positions >= cache_len are masked out."""
    b, sq, h, d = q.shape
    sk, kv = k_cache.shape[1], k_cache.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, d)
    scale = d ** -0.5
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache).astype(
        jnp.float32) * scale
    logits = shd.constrain(logits, "batch", "kv", None, None, "kvseq")
    valid = (jnp.arange(sk) < cache_len)[None, None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v_cache)
    out = out.reshape(b, sq, h, d)
    return shd.constrain(out, "batch", None, "heads", None)


def quantize_kv(x):
    """Per-token symmetric int8 quantization: x [b,s,kv,d] ->
    (int8 [b,s,kv,d], scale f32 [b,s,kv]).  Append-only friendly (each
    token carries its own scale; no requantization ever)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) /
                           scale[..., None] * 127.0), -127, 127)
    return q.astype(jnp.int8), scale


def decode_attention_q8(q, k_q, v_q, k_sc, v_sc, cache_len):
    """Grouped decode attention over an int8 KV cache (§Perf iter C2).
    Scales fold into the attention algebra instead of dequantizing the
    cache: logits = (q @ k_q^T) * k_sc and out = probs' @ v_q with
    probs' = probs * v_sc — the cache is read at 1 byte/elem."""
    b, sq, h, d = q.shape
    sk, kv = k_q.shape[1], k_q.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, d)
    scale = d ** -0.5
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg.astype(jnp.float32),
                        k_q.astype(jnp.float32) / 127.0) * scale
    logits = logits * k_sc.transpose(0, 2, 1)[:, :, None, None, :]
    logits = shd.constrain(logits, "batch", "kv", None, None, "kvseq")
    valid = (jnp.arange(sk) < cache_len)[None, None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = probs * (v_sc.transpose(0, 2, 1)[:, :, None, None, :] / 127.0)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(q.dtype),
                     v_q.astype(q.dtype))
    out = out.reshape(b, sq, h, d)
    return shd.constrain(out, "batch", None, "heads", None)


def attention(q, k, v, mask_mode: str = "causal", prefix_len: int = 0,
              unroll: bool = False):
    sq, sk = q.shape[1], k.shape[1]
    if sq == sk and sk > BLOCKWISE_THRESHOLD and sk % KV_CHUNK == 0:
        return blockwise_attention(q, k, v, mask_mode, prefix_len,
                                   unroll=unroll)
    return full_attention(q, k, v, mask_mode, prefix_len)
